"""Batched serving example: prefill-free batched decode with KV caches and
greedy/temperature sampling, reporting tokens/s — the serving-side driver.

    PYTHONPATH=src python examples/serve_batch.py --arch recurrentgemma-9b \
        --batch 8 --new-tokens 64
(uses the reduced smoke config on CPU; full configs are for the meshed dry-run)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import model as MD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch, dtype=jnp.float32)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    cache = MD.init_cache(cfg, args.batch, args.max_len)

    step = jax.jit(lambda p, c, t: MD.serve_step_fn(p, cfg, c, t))
    toks = jax.random.randint(jax.random.PRNGKey(1), (args.batch,), 0, cfg.vocab_size)
    key = jax.random.PRNGKey(2)

    # warmup/compile
    logits, cache = step(params, cache, toks)
    jax.block_until_ready(logits)

    out_tokens = [toks]
    t0 = time.time()
    for i in range(args.new_tokens):
        logits, cache = step(params, cache, toks)
        if args.temperature > 0:
            key, k = jax.random.split(key)
            toks = jax.random.categorical(k, logits / args.temperature, axis=-1)
        else:
            toks = jnp.argmax(logits, axis=-1)
        toks = toks.astype(jnp.int32)
        out_tokens.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: {total} tokens in {dt:.2f}s "
          f"({total / dt:.0f} tok/s, {dt / args.new_tokens * 1e3:.1f} ms/step, "
          f"batch={args.batch})")
    seqs = jnp.stack(out_tokens, axis=1)
    print(f"[serve] sample sequence[0][:16]: {list(map(int, seqs[0][:16]))}")


if __name__ == "__main__":
    main()
